"""Fig 3 reproduction: generator loss vs number of discriminators.

The paper trains 500 epochs on MNIST with {1,3,5,7,8} discriminators and
shows that more discriminators helps the generator minimise its loss. On
this CPU container we run a reduced DCGAN (base_filters=8, batch 32) on the
synthetic MNIST for a reduced number of epochs — the *trend* across
discriminator counts is the claim under test.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks._obs import finish, obs_over
from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist


def run(fast: bool = False, counts=(1, 3, 5), epochs: int = 12,
        batches_per_client: int = 3) -> List[Tuple[str, float, str]]:
    if fast:
        counts, epochs = (1, 3), 4
    imgs, labels = synthetic_mnist(1500, seed=0)
    rows = []
    finals = {}
    for n_disc in counts:
        cfg = get_config("dcgan-mnist").override({
            "shape.global_batch": 32,
            "fsl.num_clients": n_disc,
            "model.dcgan.base_filters": 8,
            **obs_over(f"convergence_{n_disc}d"),
        })
        parts = partition_dirichlet(imgs, labels, n_disc, alpha=0.5, seed=0)
        tr = FSLGANTrainer(cfg, parts, seed=0)
        t0 = time.time()
        hist = [tr.train_epoch(batches_per_client=batches_per_client)
                for _ in range(epochs)]
        secs = time.time() - t0
        finish(tr)
        g = [h["g_loss"] for h in hist]
        # smooth the tail (GAN losses oscillate)
        tail = float(np.mean(g[-max(2, epochs // 3):]))
        finals[n_disc] = tail
        rows.append((f"fig3_gen_loss[{n_disc}_disc]",
                     secs * 1e6 / epochs,
                     f"final_g_loss={tail:.3f} first={g[0]:.3f}"))
    ks = sorted(finals)
    trend = finals[ks[-1]] <= finals[ks[0]] + 0.15
    rows.append(("fig3_more_discs_helps", 0.0,
                 f"trend_holds={trend} finals={ {k: round(v,3) for k,v in finals.items()} }"))
    return rows
