"""Beyond-paper ablation: effect of data heterogeneity on FSL-GAN
convergence — the paper's own future-work item (iv) (§6).

Three federated partitions of the same synthetic MNIST set across 3
clients: IID, Dirichlet(0.5) (moderate skew — the reproduction default),
Dirichlet(0.1) (strong label skew). Reports the tail generator loss and
the per-client example-count spread as the skew measure.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, partition_iid, synthetic_mnist

from benchmarks._obs import finish, obs_over


def run(fast: bool = False, epochs: int = 8, clients: int = 3
        ) -> List[Tuple[str, float, str]]:
    if fast:
        epochs = 3
    imgs, labels = synthetic_mnist(1500, seed=0)
    cases = {
        "iid": lambda: partition_iid(imgs, clients, seed=0),
        "dirichlet0.5": lambda: partition_dirichlet(imgs, labels, clients,
                                                    alpha=0.5, seed=0),
        "dirichlet0.1": lambda: partition_dirichlet(imgs, labels, clients,
                                                    alpha=0.1, seed=0),
    }
    rows = []
    finals = {}
    for name, mk in cases.items():
        parts = mk()
        sizes = [len(v) for v in parts.values()]
        # each partition case leaves a recorded run under benchmarks/obs/
        # (trace + metrics + feedback — the skew-vs-convergence artifacts)
        cfg = get_config("dcgan-mnist").override({
            "shape.global_batch": 32, "fsl.num_clients": clients,
            "model.dcgan.base_filters": 8,
            **obs_over(f"heterogeneity_{name}")})
        tr = FSLGANTrainer(cfg, parts, seed=0)
        t0 = time.time()
        hist = [tr.train_epoch(batches_per_client=3) for _ in range(epochs)]
        finish(tr)
        g = [h["g_loss"] for h in hist]
        tail = float(np.mean(g[-max(2, epochs // 3):]))
        finals[name] = tail
        rows.append((f"heterogeneity_gen_loss[{name}]",
                     (time.time() - t0) * 1e6 / epochs,
                     f"final_g_loss={tail:.3f} client_sizes={sizes}"))
    rows.append(("heterogeneity_summary", 0.0,
                 f"finals={ {k: round(v, 3) for k, v in finals.items()} } "
                 "(paper future-work (iv): skew vs convergence)"))
    return rows
