"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Emits one row per (arch x shape) on the single-pod mesh with the three
terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs. This bench reads
artifacts — run ``python -m repro.launch.dryrun --all`` first (the full
sweep takes a while on one CPU core; rows appear as artifacts land).
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

from benchmarks._obs import record_rows

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def load_reports(mesh: str = "pod16x16"):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART_DIR, f"*_{mesh}.json"))):
        with open(p) as f:
            rec = json.load(f)
        out[(rec["arch"], rec["shape"])] = rec
    return out


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    recs = load_reports()
    if not recs:
        rows = [("roofline_table", 0.0,
                 "no dry-run artifacts yet; run repro.launch.dryrun --all")]
        record_rows("roofline_table", rows)
        return rows
    ok = skipped = failed = 0
    for (arch, shape), rec in sorted(recs.items()):
        name = f"roofline[{arch}|{shape}]"
        if rec.get("status") == "skipped":
            skipped += 1
            rows.append((name, 0.0, "skipped_by_design"))
            continue
        if rec.get("status") != "ok":
            failed += 1
            rows.append((name, 0.0, f"FAILED {rec.get('error','')[:80]}"))
            continue
        ok += 1
        rows.append((name, rec.get("compile_s", 0.0) * 1e6,
                     f"compute_s={rec['compute_term_s']:.3e} "
                     f"memory_s={rec['memory_term_s']:.3e} "
                     f"collective_s={rec['collective_term_s']:.3e} "
                     f"dominant={rec['dominant']} "
                     f"useful_flops={rec['useful_flops_ratio']:.2f}"))
    rows.append(("roofline_summary", 0.0,
                 f"ok={ok} skipped={skipped} failed={failed}"))
    # artifact-driven bench, no trainer — record the table as a metrics
    # JSONL under benchmarks/obs/ like every other bench's run artifacts
    record_rows("roofline_table", rows)
    return rows
