"""Quickstart: the three layers of the framework in ~a minute on CPU.

1. paper core   — split a discriminator across heterogeneous devices and
                  price the four selection strategies (Fig 2 machinery)
2. FSL-GAN      — two federated clients train a DCGAN for two rounds
3. substrate    — a reduced assigned architecture takes two LM train steps

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import DCGANConfig, reduce_for_smoke
from repro.configs.registry import get_config
from repro.core import FSLGANTrainer, make_pool, strategy_sweep
from repro.data import partition_dirichlet, synthetic_lm_batch, synthetic_mnist
from repro.models.dcgan import disc_layer_costs, disc_layer_names
from repro.models.transformer import lm_init
from repro.optim import make_optimizer
from repro.runtime import make_train_step


def demo_split_planning():
    print("=== 1. split planning & strategy pricing (paper Fig 2) ===")
    c = DCGANConfig()
    costs = disc_layer_costs(c)
    total = sum(costs.values())
    layers = [(n, 4 * costs[n] / total) for n in disc_layer_names(c)]
    pool = make_pool("paper", 5, 4, seed=0)
    res = strategy_sweep(pool, layers, seeds=range(3), compute_unit_s=0.2)
    for strat, (mean, std) in sorted(res.items(), key=lambda kv: kv[1][0]):
        print(f"  {strat:16s} slowest-client epoch: {mean:7.2f}s ± {std:.2f}")


def demo_fsl_gan():
    print("=== 2. FSL-GAN: 2 clients, 2 rounds ===")
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 16, "fsl.num_clients": 2,
        "model.dcgan.base_filters": 8})
    imgs, labels = synthetic_mnist(200, seed=0)
    parts = partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)
    tr = FSLGANTrainer(cfg, parts, seed=0)
    for ep in range(2):
        m = tr.train_epoch(batches_per_client=2)
        print(f"  round {ep}: d_loss={m['d_loss']:.3f} g_loss={m['g_loss']:.3f}")
    print(f"  generated {tr.generate(2).shape} images; plans: "
          f"{ {cid: len(p.portions) for cid, p in tr.plans.items()} } portions")


def demo_lm_substrate():
    print("=== 3. assigned-arch substrate: olmoe-1b-7b (reduced) ===")
    cfg = reduce_for_smoke(get_config("olmoe-1b-7b", "train_4k"),
                           seq_len=32, batch=4)
    m = cfg.model
    params = lm_init(jax.random.PRNGKey(0), m)
    opt = make_optimizer(cfg.optim)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg))
    for i in range(2):
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_lm_batch(4, 32, m.vocab_size, seed=i).items()}
        params, opt_state, metrics = step(params, opt_state, batch,
                                          jnp.asarray(i, jnp.int32))
        print(f"  step {i}: loss={float(metrics['loss']):.3f} "
              f"(aux={float(metrics['aux_loss']):.4f})")


if __name__ == "__main__":
    demo_split_planning()
    demo_fsl_gan()
    demo_lm_substrate()
    print("quickstart OK")
