"""Batched serving demo: prefill a mixed-length request batch, then greedy
decode — the production serving path at smoke scale.

Run: PYTHONPATH=src python examples/serve_demo.py [--arch rwkv6-1.6b]
"""
import argparse

import numpy as np

from repro.config import reduce_for_smoke
from repro.configs.registry import get_config
from repro.data import synthetic_tokens
from repro.launch.serve import Request, serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--gen-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch, "decode_32k"), seq_len=64,
                           batch=args.requests)
    rng = np.random.default_rng(0)
    reqs = [Request(i, synthetic_tokens(1, int(rng.integers(8, 33)),
                                        cfg.model.vocab_size, seed=i)[0])
            for i in range(args.requests)]
    serve_batch(cfg, reqs, args.gen_tokens)


if __name__ == "__main__":
    main()
