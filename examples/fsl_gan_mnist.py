"""End-to-end driver (the paper's experiment): FSL-GAN on (synthetic) MNIST.

Trains the DCGAN with the full FSL pipeline — central generator, federated
split discriminators, device-selection planning, FedAvg each round — for a
few hundred discriminator steps, then reports losses, the Fig-4 style
image-quality proxies, and writes artifacts under experiments/gan/.

Run: PYTHONPATH=src python examples/fsl_gan_mnist.py [--epochs 12]
(~3-5 min on this container's CPU at the default reduced width.)
"""
import argparse
import json
import os
import time

import numpy as np

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "gan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--batches-per-client", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--base-filters", type=int, default=16)
    ap.add_argument("--selection", default="sorted_multi")
    args = ap.parse_args()

    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": args.batch_size,
        "fsl.num_clients": args.clients,
        "fsl.selection": args.selection,
        "model.dcgan.base_filters": args.base_filters})
    imgs, labels = synthetic_mnist(4000, seed=0)
    parts = partition_dirichlet(imgs, labels, args.clients, alpha=0.5, seed=0)
    print(f"clients: { {k: len(v) for k, v in parts.items()} } examples")

    tr = FSLGANTrainer(cfg, parts, seed=0)
    for cid, plan in tr.plans.items():
        print(f"  {cid} plan: " + " | ".join(
            f"{p.device_id}:{','.join(p.layer_names)}" for p in plan.portions))

    t0 = time.time()
    hist = []
    steps = 0
    for ep in range(args.epochs):
        m = tr.train_epoch(batches_per_client=args.batches_per_client)
        steps += args.clients * args.batches_per_client
        hist.append(m)
        print(f"epoch {ep:3d}: d={m['d_loss']:.3f} g={m['g_loss']:.3f} "
              f"({steps} disc steps, {time.time()-t0:.0f}s)", flush=True)

    gen = tr.generate(64)
    mse = float(np.mean((gen.mean(0) - imgs.mean(0)) ** 2))
    os.makedirs(OUT, exist_ok=True)
    np.save(os.path.join(OUT, "generated.npy"), gen)
    with open(os.path.join(OUT, "history.json"), "w") as f:
        json.dump({"history": hist, "mean_image_mse": mse,
                   "total_disc_steps": steps}, f, indent=2)
    print(f"done: {steps} discriminator steps, mean-image MSE {mse:.4f}, "
          f"artifacts in {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
