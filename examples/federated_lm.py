"""FSL beyond GANs: the paper's federated-split scheme applied to an
assigned transformer architecture.

Per-client model replicas train on non-IID token shards with FedAvg every
``--local-steps`` steps (the paper's cadence). The demo compares cadences
k=1 (classic data-parallel sync) vs k=4 (FedAvg proper) on loss — and
prints the parameter-sync traffic ratio, the paper's resource argument
made quantitative: parameter averaging every k steps moves 1/k as many
bytes as per-step gradient sync at equal steps.

Run: PYTHONPATH=src python examples/federated_lm.py [--arch rwkv6-1.6b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import reduce_for_smoke
from repro.configs.registry import get_config
from repro.data import synthetic_lm_batch
from repro.models.transformer import lm_init
from repro.optim import make_optimizer
from repro.runtime import make_fsl_train_step


def run_cadence(cfg, n_clients, steps, seed=0):
    m = cfg.model
    params = lm_init(jax.random.PRNGKey(seed), m)
    opt = make_optimizer(cfg.optim)
    opt_state = opt.init(params)
    fstep = jax.jit(make_fsl_train_step(cfg, n_clients))
    cp = jax.tree.map(lambda x: jnp.broadcast_to(x[None],
                                                 (n_clients, *x.shape)),
                      params)
    co = jax.tree.map(lambda x: jnp.broadcast_to(x[None],
                                                 (n_clients, *x.shape)),
                      opt_state)
    b = cfg.shape.global_batch
    losses = []
    for i in range(steps):
        # non-IID: each client keeps its own seed stream
        bt = {k: jnp.asarray(v).reshape(n_clients, b, -1) for k, v in
              synthetic_lm_batch(b * n_clients, cfg.shape.seq_len,
                                 m.vocab_size, seed=1000 + i).items()}
        cp, co, met = fstep(cp, co, bt, jnp.asarray(i, jnp.int32))
        losses.append(float(met["loss"]))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    base = reduce_for_smoke(get_config(args.arch, "train_4k"), seq_len=32,
                            batch=4)
    base = base.override({"optim.schedule": "constant",
                          "optim.warmup_steps": 0})
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), base.model))))
    for k in (1, 4):
        cfg = base.override({"fsl.local_steps": k})
        t0 = time.time()
        losses = run_cadence(cfg, args.clients, args.steps)
        # sync traffic: k=1 averages params every step, k=4 every 4th
        syncs = len([i for i in range(args.steps) if (i + 1) % k == 0])
        mb = syncs * n_params * 4 / 2 ** 20
        print(f"local_steps={k}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"| {syncs} FedAvg rounds = {mb:.0f} MiB param traffic "
              f"({time.time()-t0:.0f}s)")
    print("cadence k divides parameter-sync traffic by k at equal steps — "
          "the paper's efficiency argument, quantified.")


if __name__ == "__main__":
    main()
