"""Privacy subsystem demo: attack -> metric -> DP defense, end to end.

Walks the honest-but-curious threat model against the paper's protocol on
the synthetic dataset, at smoke scale:

  1. train a few FSL-GAN rounds (no privacy) and ATTACK the artifacts the
     runtime ships — gradient inversion of the uplinked D gradient,
     activation inversion at a split boundary, membership inference on the
     trained D;
  2. MEASURE the leakage — reconstruction PSNR/SSIM, distance correlation
     per split depth, attack AUC;
  3. DEFEND with DP-SGD (per-example clip + Gaussian noise through the
     kernels/dp_clip path) and re-run the gradient inversion: PSNR drops
     while the RDP accountant prices the epsilon spent.

Run: PYTHONPATH=src python examples/privacy_frontier_demo.py [--epochs 2]
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro.config import DCGANConfig
from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer, d_loss_fn
from repro.data import partition_dirichlet, synthetic_mnist
from repro.kernels.dp_clip.ops import dp_clip_noise_tree
from repro.privacy import (ActivationInversionAttack, best_match_psnr,
                           distance_correlation, invert_gradients,
                           make_prefix_fn, membership_inference,
                           plan_boundary_depths, psnr, ssim)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--sigma", type=float, default=1.0,
                    help="DP noise multiplier for the defended run")
    args = ap.parse_args()

    base = {"shape.global_batch": 8, "fsl.num_clients": args.clients,
            "model.dcgan.base_filters": 8}
    imgs, labels = synthetic_mnist(600, seed=0)
    parts = partition_dirichlet(imgs, labels, args.clients, alpha=0.5,
                                seed=0)
    c = DCGANConfig(base_filters=8)
    loss_fn = functools.partial(d_loss_fn, c=c)

    # --- 1. undefended training ------------------------------------------
    print("=== training (no privacy) ===")
    tr = FSLGANTrainer(get_config("dcgan-mnist").override(base), parts,
                       seed=0)
    for ep in range(args.epochs):
        m = tr.train_epoch(batches_per_client=4)
        print(f"  ep {ep}: d={m['d_loss']:.3f} g={m['g_loss']:.3f}")
    params = tr.state.d_params[tr.client_ids[0]]

    # --- 2a. gradient inversion of the uplinked D gradient ---------------
    print("\n=== attack 1: gradient inversion (server-side) ===")
    victim = jnp.asarray(parts["c0"][:1])
    fake = 0.3 * jax.random.normal(jax.random.PRNGKey(3), victim.shape)
    g = jax.grad(loss_fn)(params, victim, fake)
    rec, hist = invert_gradients(loss_fn, params, g, fake, victim.shape,
                                 steps=200, key=jax.random.PRNGKey(7))
    print(f"  reconstruction: PSNR={best_match_psnr(rec, victim):.2f}dB "
          f"SSIM={ssim(rec, victim):.3f} match_loss={hist[-1]:.4f}")

    # --- 2b. activation inversion at the split boundaries ----------------
    print("\n=== attack 2: activation inversion (LAN observer) ===")
    plan = next(iter(tr.plans.values()))
    depths = plan_boundary_depths(plan) or [1]
    aux, _ = synthetic_mnist(256, seed=5)          # attacker's shadow data
    probe = jnp.asarray(parts["c0"][:16])
    for depth in sorted(set(depths)):
        atk = ActivationInversionAttack(make_prefix_fn(params, c, depth),
                                        (28, 28, 1), seed=0)
        atk.train(aux, steps=150, batch=32)
        rec_a = atk.reconstruct(probe)
        dcor = distance_correlation(probe, atk.prefix(probe))
        print(f"  boundary depth {depth}: PSNR={psnr(rec_a, probe):.2f}dB "
              f"dCor={dcor:.3f}")

    # --- 2c. membership inference on the trained D -----------------------
    print("\n=== attack 3: membership inference ===")
    nonmember, _ = synthetic_mnist(64, seed=99)
    mi = membership_inference(params, c, parts["c0"][:64], nonmember)
    print(f"  AUC={mi['auc']:.3f} advantage={mi['advantage']:.3f}")

    # --- 3. DP-SGD defense + re-attack ------------------------------------
    print(f"\n=== defense: DP-SGD (sigma={args.sigma}) ===")
    tr_dp = FSLGANTrainer(get_config("dcgan-mnist").override({
        **base, "privacy.enabled": True,
        "privacy.noise_multiplier": args.sigma,
        "privacy.sample_rate": 0.1}), parts, seed=0)
    for ep in range(args.epochs):
        m = tr_dp.train_epoch(batches_per_client=4)
        print(f"  ep {ep}: d={m['d_loss']:.3f} g={m['g_loss']:.3f} "
              f"epsilon={m['dp_epsilon']:.2f}")
    dp_params = tr_dp.state.d_params[tr_dp.client_ids[0]]
    per_ex = jax.vmap(
        lambda r, f: jax.grad(loss_fn)(dp_params, r[None], f[None]),
        in_axes=(0, 0))(victim, fake)
    g_dp = dp_clip_noise_tree(per_ex, 1.0, args.sigma,
                              jax.random.PRNGKey(11), use_kernel=False)
    rec_dp, _ = invert_gradients(loss_fn, dp_params, g_dp, fake,
                                 victim.shape, steps=200,
                                 key=jax.random.PRNGKey(7))
    print(f"  re-attack under DP: PSNR={best_match_psnr(rec_dp, victim):.2f}dB"
          f" (vs {best_match_psnr(rec, victim):.2f}dB undefended) at "
          f"epsilon={tr_dp.accountant.epsilon(1e-5)[0]:.2f}")


if __name__ == "__main__":
    main()
