"""Federation runtime demo: async vs sync scheduling, codecs, stragglers.

Runs the same FSL-GAN workload (paper §3, smoke scale) under four runtime
configurations and prints, per epoch, the virtual round time (the paper's
Fig-2 wall-clock model extended with WAN transfers), uplink traffic, and
losses:

  sync            the paper's barrier FedAvg (bit-identical to the seed)
  sync+deadline   barrier with straggler dropout at a deadline
  fedasync+int8   staleness-weighted async aggregation, int8 uplink codec
  fedbuff+topk    buffered async aggregation, top-k sparsified uplink

``--backend vectorized`` compiles each scenario's client program as one
jitted vmap-over-clients round instead of the per-client loop (the
scheduling x backend matrix of fed/programs.py — any scenario composes
with either backend).

Run: PYTHONPATH=src python examples/fed_async_demo.py [--epochs 4]
                                                      [--backend loop]
"""
import argparse

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist

SCENARIOS = {
    "sync": {},
    "sync+deadline": {"fed.deadline_s": 2.4e4},
    "fedasync+int8": {"fed.mode": "fedasync", "fed.codec": "int8",
                      "fed.async_cycles": 2},
    "fedbuff+topk": {"fed.mode": "fedbuff", "fed.codec": "topk",
                     "fed.topk_frac": 0.05, "fed.buffer_size": 2,
                     "fed.async_cycles": 2},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--batches-per-client", type=int, default=4)
    ap.add_argument("--backend", choices=("loop", "vectorized"),
                    default="loop")
    args = ap.parse_args()

    imgs, labels = synthetic_mnist(1000, seed=0)
    parts = partition_dirichlet(imgs, labels, args.clients, alpha=0.5,
                                seed=0)

    for name, over in SCENARIOS.items():
        cfg = get_config("dcgan-mnist").override({
            "shape.global_batch": 16, "fsl.num_clients": args.clients,
            "model.dcgan.base_filters": 8, **over})
        tr = FSLGANTrainer(cfg, parts, seed=0)
        print(f"\n=== {name} ===")
        for ep in range(args.epochs):
            m = tr.train_epoch(batches_per_client=args.batches_per_client,
                               backend=args.backend)
            print(f"  ep {ep}: d={m['d_loss']:.3f} g={m['g_loss']:.3f} "
                  f"round={m['round_time_s']:.0f}s "
                  f"clients={m['num_clients']:.0f} "
                  f"drop={m['stragglers']:.0f} "
                  f"stale={m['mean_staleness']:.2f} "
                  f"up={m['up_mbytes']:.3f}MB", flush=True)
        led = tr.engine.ledger
        print(f"  totals: up={led.total_up/1e6:.3f}MB "
              f"down={led.total_down/1e6:.3f}MB "
              f"virtual clock={tr.engine.clock:.0f}s")


if __name__ == "__main__":
    main()
