"""Device-selection walkthrough (paper §4): inspect the plans each strategy
produces for one heterogeneous client, then price them with the analytic
hop model.

This is the PLAN-ONLY view.  Since ISSUE 4 the plan also *executes*:
``examples/split_training_demo.py`` runs a federated round through the
split (staged forward/backward, boundary stages, measured LAN bytes).
Since ISSUE 5 the plan is also *controlled*: the per-device loads printed
below are exactly ``RoundFeedback.device_loads``, the field the split
controller watches to re-run this very planning when the measured
imbalance drifts — ``examples/adaptive_control_demo.py`` closes that loop.

Run: PYTHONPATH=src python examples/device_selection_demo.py
"""
from repro.config import DCGANConfig
from repro.core.devices import Client, Device
from repro.core.selection import STRATEGIES, make_plan
from repro.core.simulate import plan_epoch_time
from repro.models.dcgan import disc_layer_costs, disc_layer_names


def main():
    c = DCGANConfig()
    costs = disc_layer_costs(c)
    total = sum(costs.values())
    layers = [(n, 4 * costs[n] / total) for n in disc_layer_names(c)]

    client = Client("demo", [
        Device("phone", time_factor=0.4, capacity=2),    # fast, small
        Device("tablet", time_factor=1.0, capacity=2),
        Device("old-pc", time_factor=2.5, capacity=4),   # slow, roomy
        Device("watch", time_factor=0.6, capacity=1),    # fast, tiny
    ])
    print("devices (efficiency = capacity/time_factor):")
    for d in client.devices:
        print(f"  {d.device_id:8s} tf={d.time_factor:.1f} cap={d.capacity} "
              f"eff={d.efficiency:.2f}")

    print(f"\nmodel: {[n for n, _ in layers]} "
          f"(costs {[round(v, 2) for _, v in layers]})")
    for strat in STRATEGIES:
        plan = make_plan(client, layers, strat, seed=1)
        t = plan_epoch_time(plan, client, compute_unit_s=0.2)
        route = " -> ".join(f"{p.device_id}[{','.join(p.layer_names)}]"
                            for p in plan.portions)
        loads = plan.device_loads()
        imb = max(loads.values()) / (sum(loads.values()) / len(loads))
        print(f"\n{strat} (epoch {t:.1f}s, {plan.num_boundaries} LAN hops):")
        print(f"  {route}")
        print(f"  RoundFeedback.device_loads = "
              f"{ {k: round(v, 2) for k, v in loads.items()} } "
              f"(max/mean imbalance {imb:.2f} — the split controller "
              f"replans past control.imbalance_threshold)")

    print("\nnext: examples/split_training_demo.py EXECUTES a plan "
          "(staged training, measured LAN bytes, boundary leakage); "
          "examples/adaptive_control_demo.py CONTROLS it (replan + "
          "per-boundary noise from measured drift).")


if __name__ == "__main__":
    main()
