"""Flight-recorder trace walkthrough (ISSUE 6): run a federated split
round with tracing on, export Chrome-trace JSON, and read it back —
plus the watchtower layer on top (ISSUE 7): health alerts and per-round
state digests printed alongside the spans.

The engine emits nested spans on its discrete-event virtual clock for
round -> downlink -> client execution -> batch -> split segment ->
boundary crossing -> uplink -> aggregate.  The exporter writes the
standard Chrome trace format, so the output opens directly in
`chrome://tracing` or https://ui.perfetto.dev — drag the file in and the
round unfolds as a timeline: one server track plus one track per client,
with every LAN boundary crossing (activation fwd, activation-grad bwd)
visible inside each batch.

Run: PYTHONPATH=src python examples/trace_viewer_demo.py
     -> writes obs_runs/trace-demo/trace.json
"""
import json
import os
from collections import Counter

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.obs import validate_chrome_trace

CLIENTS = 2
OUT = os.path.join("obs_runs")


def main():
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 8,
        "fsl.num_clients": CLIENTS,
        "model.dcgan.base_filters": 8,
        "split.enabled": True,
        "fed.client_local_steps": {"c1": 2},   # a visible straggler tail
        "obs.enabled": True,
        "obs.out_dir": OUT,
        "obs.run_id": "trace-demo",
        # the watchtower (ISSUE 7): numeric-health monitors on every
        # round, warn-only policy — a healthy demo prints zero alerts
        "obs.health.enabled": True,
        "obs.health.policy": "warn",
    })
    imgs, labels = synthetic_mnist(60 * CLIENTS, seed=0)
    parts = partition_dirichlet(imgs, labels, CLIENTS, alpha=0.5, seed=0)
    tr = FSLGANTrainer(cfg, parts, seed=0)

    print("== two traced federated split rounds ==")
    for _ in range(2):
        m = tr.train_epoch(batches_per_client=2)
        print(f"  d_loss {m['d_loss']:.4f}  round {m['round_time_s']:.1f}s "
              f"(virtual)")
    tr.recorder.flush()

    trace_path = tr.recorder.path("trace.json")
    with open(trace_path) as f:
        obj = json.load(f)
    n = validate_chrome_trace(obj)
    print(f"\n== {trace_path}: {n} events, schema-valid ==")
    cats = Counter(s.cat for s in tr.recorder.tracer.spans)
    for cat in ("round", "downlink", "client", "batch", "segment",
                "boundary", "uplink", "aggregate"):
        print(f"  {cat:>9}: {cats.get(cat, 0):>3} spans")

    print("\n== one batch, span by span (virtual clock) ==")
    tracer = tr.recorder.tracer
    batch = min(tracer.by_cat("batch"), key=lambda s: s.v_start)
    print(f"  {batch.name} on {batch.track}: "
          f"[{batch.v_start:.2f}, {batch.v_end:.2f}]s")
    for child in sorted(tracer.children(batch.span_id),
                        key=lambda s: s.v_start):
        tag = (f" ({child.args.get('direction')} b"
               f"{child.args.get('boundary')})"
               if child.cat == "boundary" else "")
        print(f"    {child.v_start:9.3f} -> {child.v_end:9.3f}  "
              f"{child.cat:>8}  {child.name}{tag}")

    print("\n== watchtower: health alerts + state digests ==")
    if tr.health_alerts:
        for a in tr.health_alerts:
            print(f"  round {a.round_index} [{a.severity:>5}] "
                  f"{a.check}: {a.message}")
    else:
        print("  no health alerts (all monitors quiet — see "
              "alerts.jsonl for the persisted record)")
    for d in tr.recorder.digests:
        print(f"  round {d.round_index} global digest {d.global_digest} "
              f"l2={d.global_sketch[0]:.4f}"
              f"{'  (ROLLED BACK)' if d.rolled_back else ''}")

    print(f"\nopen {trace_path} in chrome://tracing or ui.perfetto.dev — "
          "pid 1 is the virtual clock, one thread per client track.")


if __name__ == "__main__":
    main()
