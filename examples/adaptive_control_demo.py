"""Closed-loop control walkthrough (ISSUE 5): measure -> decide -> retune,
every round.

Four controllers run simultaneously on one federated split-GAN run:

  codec    — probes the uplink-codec frontier cheapest-first and commits
             to the cheapest codec whose measured delta error fits the
             budget (watch the codec column change);
  sigma    — spends a total (epsilon, delta) DP budget over the horizon by
             inverting the RDP curve each round (epsilon climbs TO the
             budget, never past it);
  split    — replans device selection when measured load imbalance drifts
             and noises only the boundaries whose measured dCor says they
             leak;
  deadline — sets the sync straggler deadline at a quantile of the
             measured per-client finish-time distribution.

Every decision is computed from the previous rounds' RoundFeedback records
alone (control/feedback.py) — the same typed record this demo prints, so
the output doubles as the feedback schema documentation.

Run: PYTHONPATH=src python examples/adaptive_control_demo.py
"""
import numpy as np

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist

CLIENTS = 2
ROUNDS = 4
EPS_BUDGET = 4.0


def main():
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 8,
        "fsl.num_clients": CLIENTS,
        "fsl.selection": "random_single",      # deliberately imbalanced
        "model.dcgan.base_filters": 8,
        "split.enabled": True,
        "split.stage_clip": 5.0,
        "split.stage_sigma": 0.5,
        "privacy.enabled": True,
        "privacy.mode": "uplink",
        "privacy.noise_multiplier": 1.0,
        "fed.client_local_steps": {"c1": 3},   # a built-in straggler
        "control.mode": "adaptive",
        "control.controllers": ["codec", "sigma", "split", "deadline"],
        "control.error_budget": 0.05,
        "control.epsilon_budget": EPS_BUDGET,
        "control.horizon_rounds": ROUNDS,
        "control.imbalance_threshold": 1.2,
        "control.dcor_threshold": 0.3,
        "control.deadline_quantile": 0.5,
        "control.deadline_slack": 1.6,
        "control.probe_batch": 8,
    })
    imgs, labels = synthetic_mnist(60 * CLIENTS, seed=0)
    parts = partition_dirichlet(imgs, labels, CLIENTS, alpha=0.5, seed=0)
    tr = FSLGANTrainer(cfg, parts, seed=0)

    print(f"== {ROUNDS} adaptive rounds "
          f"(eps budget {EPS_BUDGET}, error budget 0.05) ==")
    hdr = (f"{'r':>2} {'codec':>6} {'err':>7} {'up_kB':>7} {'sigma':>6} "
           f"{'eps':>6} {'deadline':>9} {'strat':>13} {'straggl':>7}")
    print(hdr)
    for r in range(ROUNDS):
        m = tr.train_epoch(batches_per_client=1)
        fb = tr.feedback[-1]
        print(f"{r:>2} {fb.codec:>6} {fb.codec_error:7.4f} "
              f"{fb.up_bytes / 1e3:7.1f} {fb.sigma:6.2f} "
              f"{fb.dp_epsilon:6.3f} {fb.deadline_s:9.1f} "
              f"{fb.split_strategy:>13} {fb.stragglers:>7}")
    assert fb.dp_epsilon <= EPS_BUDGET, "sigma controller overspent!"

    print("\n== per-boundary stage assignment after dCor drift ==")
    for cid, ex in sorted(tr.split_execs.items()):
        dcor = tr.feedback[-1].boundary_dcor.get(cid, ())
        stages = [s.name for s in ex.stages]
        print(f"  {cid}: stages={stages} measured dCor="
              f"{[round(v, 2) for v in dcor]}")

    print("\n== the RoundFeedback record the controllers consumed ==")
    for k, v in tr.feedback[-1].summary().items():
        print(f"  {k:>16}: {v}")
    print("\nfields -> controllers: codec/up_bytes/codec_error -> codec; "
          "sigma/dp_steps/dp_epsilon -> sigma; device_loads/boundary_dcor "
          "-> split; client_finish_s -> deadline.")


if __name__ == "__main__":
    main()
