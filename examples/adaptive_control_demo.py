"""Closed-loop control walkthrough (ISSUE 5 + 6): measure -> decide ->
retune, every round — with the flight recorder keeping the books.

Four controllers run simultaneously on one federated split-GAN run:

  codec    — probes the uplink-codec frontier cheapest-first and commits
             to the cheapest codec whose measured delta error fits the
             budget (watch the codec column change);
  sigma    — spends a total (epsilon, delta) DP budget over the horizon by
             inverting the RDP curve each round (epsilon climbs TO the
             budget, never past it);
  split    — replans device selection when measured load imbalance drifts
             and noises only the boundaries whose measured dCor says they
             leak;
  deadline — sets the sync straggler deadline at a quantile of the
             measured per-client finish-time distribution.

Since ISSUE 6 every round's RoundFeedback + the knob decision it produced
land in the flight recorder (``repro.obs``): the table below is rendered
from the recorder's typed metrics registry, and at the end the recorded
feedback JSONL is replayed OFFLINE through the same pure controllers —
reproducing the live knob sequence bit-exactly.  That replay loop is how
controllers get tuned without rerunning training (ROADMAP item 4).

Run: PYTHONPATH=src python examples/adaptive_control_demo.py
     -> writes obs_runs/adaptive-demo/{feedback,knobs,metrics}.jsonl + trace.json
"""
from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.obs import load_run, replay_run

CLIENTS = 2
ROUNDS = 4
EPS_BUDGET = 4.0


def main():
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 8,
        "fsl.num_clients": CLIENTS,
        "fsl.selection": "random_single",      # deliberately imbalanced
        "model.dcgan.base_filters": 8,
        "split.enabled": True,
        "split.stage_clip": 5.0,
        "split.stage_sigma": 0.5,
        "privacy.enabled": True,
        "privacy.mode": "uplink",
        "privacy.noise_multiplier": 1.0,
        "fed.client_local_steps": {"c1": 3},   # a built-in straggler
        "control.mode": "adaptive",
        "control.controllers": ["codec", "sigma", "split", "deadline"],
        "control.error_budget": 0.05,
        "control.epsilon_budget": EPS_BUDGET,
        "control.horizon_rounds": ROUNDS,
        "control.imbalance_threshold": 1.2,
        "control.dcor_threshold": 0.3,
        "control.deadline_quantile": 0.5,
        "control.deadline_slack": 1.6,
        "control.probe_batch": 8,
        "obs.enabled": True,
        "obs.out_dir": "obs_runs",
        "obs.run_id": "adaptive-demo",
    })
    imgs, labels = synthetic_mnist(60 * CLIENTS, seed=0)
    parts = partition_dirichlet(imgs, labels, CLIENTS, alpha=0.5, seed=0)
    tr = FSLGANTrainer(cfg, parts, seed=0)
    reg = tr.recorder.registry

    print(f"== {ROUNDS} adaptive rounds, recorded "
          f"(eps budget {EPS_BUDGET}, error budget 0.05) ==")
    hdr = (f"{'r':>2} {'codec':>6} {'err':>7} {'up_kB':>7} {'sigma':>6} "
           f"{'eps':>6} {'deadline':>9} {'straggl':>7}")
    print(hdr)
    up_prev = 0
    for r in range(ROUNDS):
        tr.train_epoch(batches_per_client=1)
        # every column below reads the recorder's typed registry — the
        # same numbers metrics.jsonl persists for offline tooling
        fb, k = tr.feedback[-1], tr.knobs
        up = reg["wire.up_bytes"].value
        print(f"{r:>2} {k.codec:>6} {reg['codec.rel_error'].value:7.4f} "
              f"{(up - up_prev) / 1e3:7.1f} {fb.sigma:6.2f} "
              f"{reg['privacy.epsilon'].value:6.3f} {k.deadline_s:9.1f} "
              f"{reg['fed.straggler_drops'].value:7.0f}")
        up_prev = up
    assert reg["privacy.epsilon"].value <= EPS_BUDGET, "sigma overspent!"
    tr.recorder.flush()

    print("\n== the registry after the run (metrics.jsonl, last line) ==")
    print(tr.recorder.render_summary())

    print("== offline replay of the recorded run ==")
    run_dir = tr.recorder.run_dir
    rec = load_run(run_dir)
    res = replay_run(run_dir)
    print(f"  {run_dir}: {rec.num_rounds} rounds of RoundFeedback")
    print(f"  replayed through the pure controller fold: "
          f"matches live decisions bit-exactly = {res.matches}")
    for r, k in enumerate(res.decisions):
        stages = dict(sorted((k.stage_by_boundary or {}).items()))
        print(f"  r{r}: codec={k.codec:>5} sigma={k.sigma:.3f} "
              f"deadline={k.deadline_s:7.1f} stages={stages or '{}'}")
    assert res.matches

    print("\nfields -> controllers: codec/up_bytes/codec_error -> codec; "
          "sigma/dp_steps/dp_epsilon -> sigma; device_loads/boundary_dcor "
          "-> split; client_finish_s -> deadline.  Tune a controller by "
          "editing it and re-running replay_run() on this directory — no "
          "training required.")


if __name__ == "__main__":
    main()
