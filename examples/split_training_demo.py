"""Executed split training walkthrough (ISSUE 4): plan -> run the round
THROUGH the split -> measure what it cost and what it leaked.

The seed repo only *priced* a SplitPlan; here the plan is the local step:
each client's discriminator trains device-segment by device-segment, every
boundary tensor (activation forward, activation-grad backward) crosses the
LAN through the configured boundary stage, and the round reports measured
per-device load + LAN bytes.  A final readout attacks the tensors the
round actually shipped (post-stage), per boundary.

Since ISSUE 5 every one of these measurements lands in a typed
``RoundFeedback`` record, and since ISSUE 6 the flight recorder
(``repro.obs``) persists them all: the cost readouts below are rendered
from the recorder's metrics registry (the same numbers
``metrics.jsonl`` carries), and the run leaves a Chrome-trace file with
one span per boundary crossing — see ``examples/trace_viewer_demo.py``.
``examples/adaptive_control_demo.py`` closes the loop on these
measurements; ``examples/device_selection_demo.py`` is the plan-only view.

Run: PYTHONPATH=src python examples/split_training_demo.py
     -> writes obs_runs/split-demo-*/{metrics,feedback}.jsonl + trace.json
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.core.split import partition_params
from repro.data import partition_dirichlet, synthetic_mnist
from repro.fed.transport import tree_bytes
from repro.privacy import (ActivationInversionAttack, best_match_psnr,
                           distance_correlation, make_shipped_prefix_fn)

CLIENTS = 2
BATCHES = 2


def build_trainer(stage: str) -> FSLGANTrainer:
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 8,
        "fsl.num_clients": CLIENTS,
        "model.dcgan.base_filters": 8,
        "split.enabled": True,
        "split.boundary_stage": stage,
        "split.stage_clip": 5.0,
        "split.stage_sigma": 0.5,
        "obs.enabled": True,
        "obs.out_dir": "obs_runs",
        "obs.run_id": f"split-demo-{stage}",
    })
    imgs, labels = synthetic_mnist(60 * CLIENTS, seed=0)
    parts = partition_dirichlet(imgs, labels, CLIENTS, alpha=0.5, seed=0)
    return FSLGANTrainer(cfg, parts, seed=0)


def main():
    tr = build_trainer("identity")

    print("== the plans the round will EXECUTE ==")
    for cid, plan in tr.plans.items():
        route = " -> ".join(f"{p.device_id}[{','.join(p.layer_names)}]"
                            for p in plan.portions)
        ex = tr.split_execs[cid]
        print(f"  {cid}: {route}  ({ex.num_boundaries} LAN boundaries, "
              f"signature {ex.signature[0]})")

    print("\n== one federated round, trained through the split ==")
    m = tr.train_epoch(batches_per_client=BATCHES)
    reg = tr.recorder.registry
    print(f"  d_loss {reg['gan.d_loss'].value:.4f}  "
          f"g_loss {reg['gan.g_loss'].value:.4f}")
    print(f"  round time      {reg['fed.round_time_s'].value:.1f}s "
          f"(virtual, priced from MEASURED boundary bytes)")
    print(f"  LAN boundary    {reg['wire.lan_bytes'].value / 1e6:.3f} MB "
          f"shipped this round")
    print(f"  WAN up/down     {reg['wire.up_bytes'].value / 1e6:.3f} / "
          f"{reg['wire.down_bytes'].value / 1e6:.3f} MB")
    print("  per-client wire (ledger observer -> registry):")
    for cid in sorted(tr._active_clients()):
        print(f"    {cid}: up {reg[f'wire.client.{cid}.up_bytes'].value:>9.0f} B"
              f"  lan {reg[f'wire.client.{cid}.lan_bytes'].value:>9.0f} B")

    print("\n== the RoundFeedback the round emitted "
          "(recorded to feedback.jsonl; what the split controller reads) ==")
    fb = tr.recorder.feedback[-1]
    print(f"  lan_bytes={fb.lan_bytes}  up_bytes={fb.up_bytes}  "
          f"round_time_s={fb.round_time_s:.1f}")
    print(f"  device_loads (imbalance drift -> replan): "
          f"{ {k: round(v) for k, v in fb.device_loads.items()} }")
    print(f"  client_finish_s (deadline controller): "
          f"{ {k: round(v, 1) for k, v in fb.client_finish_s.items()} }")
    print("  boundary_dcor fills in under control.mode='adaptive' "
          "(examples/adaptive_control_demo.py)")
    tr.recorder.flush()
    print(f"  trace with per-boundary spans -> "
          f"{tr.recorder.path('trace.json')}")

    print("\n== per-device load (compute units / resident D params) ==")
    param_bytes = {}
    for cid, plan in tr.plans.items():
        parts = partition_params(plan, tr.state.d_params[cid])
        for portion, sub in zip(plan.portions, parts):
            param_bytes[portion.device_id] = \
                param_bytes.get(portion.device_id, 0) + tree_bytes(sub)
    for dev, load in sorted(tr.device_load_report().items()):
        print(f"  {dev:8s} {load:12.0f} units  "
              f"{param_bytes.get(dev, 0) / 1e3:8.1f} kB params")

    print("\n== boundary leakage of the tensors the round ACTUALLY ships ==")
    aux, _ = synthetic_mnist(48, seed=5)
    victim, _ = synthetic_mnist(16, seed=9)
    aux, victim = jnp.asarray(aux), jnp.asarray(victim)
    for stage in ("identity", "int8", "dp"):
        t = tr if stage == "identity" else build_trainer(stage)
        if stage != "identity":
            t.train_epoch(batches_per_client=BATCHES)
        cid = max(t._active_clients(),
                  key=lambda c: t.split_execs[c].num_boundaries)
        ex = t.split_execs[cid]
        d_params = t.state.d_params[cid]
        for b in range(ex.num_boundaries):
            prefix = make_shipped_prefix_fn(ex, d_params, b,
                                            key=jax.random.PRNGKey(13))
            atk = ActivationInversionAttack(prefix, (28, 28, 1), width=16)
            atk.train(aux, steps=60, batch=16)
            psnr = best_match_psnr(atk.reconstruct(victim), victim)
            dcor = distance_correlation(victim, prefix(victim))
            wire = ex.stages[b].wire_bytes(ex.boundary_shapes(
                d_params, (t.batch_size,) + victim.shape[1:])[b])
            print(f"  stage={stage:8s} boundary {b} "
                  f"(depth {ex.boundaries[b].depth}): "
                  f"dCor={dcor:.3f}  inversion PSNR={psnr:5.2f} dB  "
                  f"wire={wire} B/pass")
    print("\nlossier/noisier stages ship fewer recoverable bits across the "
          "LAN — the trade the paper's privacy claim rests on, now "
          "measured on the executed round.")


if __name__ == "__main__":
    main()
