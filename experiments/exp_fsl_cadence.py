"""Paper-representative perf experiment: FedAvg cadence vs sync traffic.

Lowers three training regimes for one arch on the production mesh and
compares per-step cross-client collective bytes:

  dp        standard data-parallel train_step (grad all-reduce every step)
  fsl_k1    per-client replicas, FedAvg every step
  fsl_k8    per-client replicas, FedAvg every 8th step (amortized /8)

The FSL mode maps the paper's scheme onto the mesh: clients = data-axis
groups, the only cross-client collective is the parameter average, and the
cadence divides that traffic — the paper's communication-efficiency claim
made measurable on the pod.

Run (after the single-pod sweep finishes; ~10 min):
  PYTHONPATH=src python experiments/exp_fsl_cadence.py [--arch qwen3-14b]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import collective_bytes_from_hlo
from repro.runtime import make_fsl_train_step
from repro.sharding.specs import make_activation_policy, set_activation_policy


def lower_fsl(cfg, mesh, n_clients: int, local_steps: int):
    """FSL mode: the client axis *owns* `data`; inside a client there is no
    FSDP and no batch-data sharding (rules cleared), only TP over `model`."""
    cfg = cfg.override({"fsl.local_steps": local_steps,
                        "parallel.fsdp": False})
    rules = S.make_rules(cfg, mesh)
    rules.rules["batch"] = None     # `data` is the client axis now
    rules.rules["embed"] = None
    set_activation_policy(make_activation_policy(mesh, rules))
    try:
        from repro.models.transformer import lm_specs
        from repro.sharding.specs import tree_shardings
        pshapes = S.param_shapes(cfg)
        psh = tree_shardings(mesh, rules, pshapes, lm_specs(cfg.model))
        oshapes = S.opt_shapes(cfg, pshapes)
        osh = {k: (psh if k in ("m", "v", "mom")
                   else NamedSharding(mesh, P()))
               for k in oshapes}
        ins = S.input_specs(cfg)
        data_ax = "data"

        def stack_shape(t):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_clients, *s.shape),
                                               s.dtype), t)

        def stack_shard(t):
            # client axis over `data`; inner spec keeps only model axes
            def push(ns):
                return NamedSharding(mesh, P(data_ax, *ns.spec))
            return jax.tree.map(push, t)

        cp, co = stack_shape(pshapes), stack_shape(oshapes)
        cpsh, cosh = stack_shard(psh), stack_shard(osh)
        cb = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_clients, *s.shape), s.dtype),
            ins)
        cbsh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(data_ax)), ins)
        step = make_fsl_train_step(cfg, n_clients)
        rep = NamedSharding(mesh, P())
        with mesh:
            lowered = jax.jit(step, in_shardings=(cpsh, cosh, cbsh, rep),
                              out_shardings=(cpsh, cosh, rep),
                              donate_argnums=(0, 1)).lower(
                cp, co, cb, jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
        return compiled
    finally:
        set_activation_policy(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--clients", type=int, default=16)
    args = ap.parse_args()

    mesh = make_production_mesh()
    base = get_config(args.arch, "train_4k")
    # per-client batch = global/clients so total tokens match the dp step
    cfg = base.override({
        "shape.global_batch": base.shape.global_batch // args.clients,
        "parallel.microbatches": 1,
    })

    results = {}
    for name, k in (("fsl_k1", 1), ("fsl_k8", 8)):
        compiled = lower_fsl(cfg, mesh, args.clients, k)
        coll = collective_bytes_from_hlo(compiled.as_text())
        mem = compiled.memory_analysis()
        results[name] = {
            "collective_bytes_text": coll["total"],
            "amortized_fedavg_divisor": k,
            "temp_gib": mem.temp_size_in_bytes / 2 ** 30,
        }
        print(f"{name}: text-collectives={coll['total']:.3e}B "
              f"(fedavg executes 1/{k} steps) temp={results[name]['temp_gib']:.1f}GiB",
              flush=True)

    out = os.path.join(os.path.dirname(__file__), "fsl_cadence.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
