"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

Usage: PYTHONPATH=src python experiments/make_tables.py
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "dryrun")


def load(mesh):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART, f"*_{mesh}.json"))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_b(x):
    for unit, d in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if abs(x) >= d:
            return f"{x/d:.1f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(mesh):
    recs = load(mesh)
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | mode | status | compile | args/chip | temp/chip | fits 16GiB |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s), r in sorted(recs.items()):
        if r["status"] == "skipped":
            print(f"| {a} | {s} | — | SKIP (by design) | — | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {a} | {s} | — | **FAIL** | — | — | — | — |")
            continue
        tot = r["arg_bytes_per_device"] + r["temp_bytes_per_device"]
        fits = "yes" if tot <= 16 * 2**30 else f"no ({fmt_b(tot)})"
        print(f"| {a} | {s} | {r['mode']} | ok | {r['compile_s']:.0f}s "
              f"| {fmt_b(r['arg_bytes_per_device'])} "
              f"| {fmt_b(r['temp_bytes_per_device'])} | {fits} |")


def roofline_table():
    recs = load("pod16x16")
    print("\n| arch | shape | compute (s) | memory (s) | collective (s) "
          "| dominant | 6ND/chip | HLO flops/chip | useful |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (a, s), r in sorted(recs.items()):
        if r["status"] != "ok":
            continue
        print(f"| {a} | {s} | {r['compute_term_s']:.2e} "
              f"| {r['memory_term_s']:.2e} | {r['collective_term_s']:.2e} "
              f"| **{r['dominant']}** | {r['model_flops']/r['chips']:.2e} "
              f"| {r['hlo_flops']:.2e} | {r['useful_flops_ratio']:.2f} |")


if __name__ == "__main__":
    print("## Generated dry-run tables")
    for mesh in ("pod16x16", "pod2x16x16"):
        dryrun_table(mesh)
    print("\n## Generated roofline table (single pod, 256 chips)")
    roofline_table()
